from repro.storage.aio import AsyncIOEngine, ReadTicket
from repro.storage.backend import (Backend, DRAMBackend, FileBackend,
                                   SimulatedSSD, StorageArray, make_array)
from repro.storage.chunk_store import AsyncRead, ChunkStore, LayerRead
from repro.storage.shard import (HostShard, NICLink, ShardTopology,
                                 flatten_shards, make_shards)
from repro.storage.two_stage import DirectSaver, SnapshotTask, TwoStageSaver

__all__ = ["AsyncIOEngine", "ReadTicket", "Backend", "DRAMBackend",
           "FileBackend", "SimulatedSSD", "StorageArray", "make_array",
           "AsyncRead", "ChunkStore", "LayerRead", "HostShard", "NICLink",
           "ShardTopology", "flatten_shards", "make_shards", "DirectSaver",
           "SnapshotTask", "TwoStageSaver"]
