from repro.storage.backend import (Backend, DRAMBackend, FileBackend,
                                   SimulatedSSD, StorageArray, make_array)
from repro.storage.chunk_store import ChunkStore
from repro.storage.two_stage import DirectSaver, SnapshotTask, TwoStageSaver

__all__ = ["Backend", "DRAMBackend", "FileBackend", "SimulatedSSD",
           "StorageArray", "make_array", "ChunkStore", "DirectSaver",
           "SnapshotTask", "TwoStageSaver"]
