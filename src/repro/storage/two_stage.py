"""Two-stage hidden-state saving (paper §4.2.2).

Stage 1 — snapshot: the device buffer holding one layer's hidden states for
the whole decode batch is copied to a host staging ring in a single
contiguous copy (the cudaMemcpy analog; on TPU a device→host DMA). The
compute stream only ever waits when the ring is full (backpressure).

Stage 2 — a host daemon drains the ring, splits the batch snapshot into
per-sequence rows, and appends them to the ChunkStore (which assembles the
small rows into large chunks — the write pattern storage favors).

``DirectSaver`` is the ablation baseline (Fig 14): it writes each row
synchronously to the store, charging the device write time to the caller.

Both savers also keep *virtual-time* accounting (`stall_time`) so the TBT
benchmark can compare against the decode-layer time without real disks.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import List, Optional, Sequence, Tuple

import numpy as np

from repro.config.hardware import DRAM_BW
from repro.storage.chunk_store import ChunkStore


@dataclasses.dataclass
class SnapshotTask:
    session_ids: Sequence[str]
    stream: str
    layer: int
    start_tokens: Sequence[int]       # per-sequence token offset
    data: np.ndarray                  # (batch, n_tokens, width); with
    #                                   ``layers`` set: (L, batch, n, width)
    # layer-stacked form: one snapshot covers these layers for the whole
    # decode batch (ONE ring submission per step instead of L) — the
    # stage-2 daemon splits per (layer, sequence) row. ``layer`` is
    # ignored when set.
    layers: Optional[Sequence[int]] = None


def _append_task_rows(store: ChunkStore, task: SnapshotTask) -> None:
    """Split a snapshot into per-sequence (and per-layer, for the
    stacked form) rows and append them to the chunk store."""
    data = task.data
    if task.layers is not None:
        for j, layer in enumerate(task.layers):
            for b, sid in enumerate(task.session_ids):
                if sid is None:
                    continue
                store.append_tokens(sid, task.stream, layer,
                                    task.start_tokens[b], data[j, b])
        return
    for b, sid in enumerate(task.session_ids):
        if sid is None:
            continue
        store.append_tokens(sid, task.stream, task.layer,
                            task.start_tokens[b], data[b])


class TwoStageSaver:
    """Snapshot ring + background chunk-assembly daemon."""

    def __init__(self, store: ChunkStore, ring_slots: int = 64,
                 host_bw: float = DRAM_BW, n_threads: int = 2):
        self.store = store
        self.ring: "queue.Queue[Optional[SnapshotTask]]" = queue.Queue(
            maxsize=ring_slots)
        self.host_bw = host_bw
        self.stall_time = 0.0             # virtual seconds the caller waited
        self.snapshot_time = 0.0          # virtual seconds of stage-1 copies
        self._exc: Optional[BaseException] = None
        self._exc_lock = threading.Lock()
        self._threads = [threading.Thread(target=self._daemon, daemon=True)
                         for _ in range(n_threads)]
        for t in self._threads:
            t.start()

    # ------------------------------------------------------------- stage 1
    def snapshot(self, task: SnapshotTask) -> float:
        """Submit one layer's hidden states. Returns the virtual stage-1
        cost (host copy time); blocks only if the ring is full."""
        copy_t = task.data.nbytes / self.host_bw
        self.snapshot_time += copy_t
        try:
            self.ring.put_nowait(task)
        except queue.Full:
            self.stall_time += copy_t          # backpressure: caller stalls
            self.ring.put(task)
        return copy_t

    # ------------------------------------------------------------- stage 2
    def _daemon(self):
        while True:
            task = self.ring.get()
            if task is None:
                self.ring.task_done()
                return
            try:
                _append_task_rows(self.store, task)
            except BaseException as e:   # noqa: BLE001 — losing a write
                # silently would corrupt the store; surface via drain()
                with self._exc_lock:
                    if self._exc is None:
                        self._exc = e
            finally:
                self.ring.task_done()

    def drain(self):
        self.ring.join()
        with self._exc_lock:
            exc, self._exc = self._exc, None
        if exc is not None:
            raise exc

    def close(self):
        try:
            self.drain()
        finally:
            for _ in self._threads:
                self.ring.put(None)
            for t in self._threads:
                t.join()


class DirectSaver:
    """Fig 14 ablation: synchronous per-row writes to the store, charging
    the device write time to the decode critical path."""

    def __init__(self, store: ChunkStore):
        self.store = store
        self.stall_time = 0.0
        self.snapshot_time = 0.0

    def snapshot(self, task: SnapshotTask) -> float:
        before = _write_busy(self.store)
        _append_task_rows(self.store, task)
        stall = _write_busy(self.store) - before
        self.stall_time += stall
        return stall

    def drain(self):
        pass

    def close(self):
        pass


def _write_busy(store: ChunkStore) -> float:
    from repro.storage.backend import SimulatedSSD
    return sum(d.write_time_total for d in store.devices
               if isinstance(d, SimulatedSSD))
