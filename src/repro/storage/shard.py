"""Host shards for the distributed chunk store (DESIGN.md §15).

A ``HostShard`` is one remote host's slice of the store: a device array
(its local SSDs / DRAM / files) reachable only through a ``NICLink`` —
a bandwidth + RTT + per-link-queue model in the same ``SimClock`` style
as ``SimulatedSSD``. A chunk read through a shard first occupies the
owning device (device clock) and then the shard's NIC (link clock); the
returned completion is the link's, so striped restores are priced on the
links they actually touch, not a single global storage figure.

``ShardTopology`` is the placement policy — which shard owns which
(layer, chunk):

  * ``layer`` — layer-striped: layer L lives wholly on shard L % N. A
    layer read touches ONE link; different layers' reads proceed on
    different links in parallel (the restoration replay models the IO
    stream per link).
  * ``chunk`` — token-chunk-striped: chunk C of every layer lives on
    shard C % N. A layer read fans over ALL links and aggregates their
    bandwidth (long histories), at the price of every restore contending
    on every link.

The topology is persisted in each session manifest (the owner map), so
a store reopened with a different shard count can still locate chunks
(placement fallback in ``ChunkStore._backend_for``) and a future remote
restore knows which host to target per stripe.
"""
from __future__ import annotations

import dataclasses
import os
import threading
from typing import List, Optional, Sequence, Tuple

from repro.config.hardware import NIC_BW, NIC_RTT
from repro.storage.backend import (Backend, DRAMBackend, FileBackend,
                                   SimClock, SimulatedSSD, StorageArray)

PLACEMENTS = ("layer", "chunk")


class NICLink:
    """Per-shard NIC model: bandwidth + RTT with a serial transfer queue.

    Same virtual-clock style as ``SimulatedSSD``: a transfer starts at
    ``max(now, queue busy-until, data ready)`` and occupies the link for
    ``rtt + nbytes / bandwidth`` seconds. ``read_time_total`` accrues
    link service seconds for the profiler (the per-link rate signal).
    Clock arithmetic is lock-guarded — the async IO engine drives links
    from per-shard worker threads while the engine thread issues inline
    metadata reads."""

    def __init__(self, bandwidth: float = NIC_BW, rtt: float = NIC_RTT,
                 shard_id: int = 0):
        self.bandwidth = float(bandwidth)
        self.rtt = float(rtt)
        self.shard_id = int(shard_id)
        self.clock = SimClock()
        self.now = 0.0
        self.read_time_total = 0.0
        self.write_time_total = 0.0
        self._lock = threading.Lock()

    def charge_read(self, nbytes: int, ready: float = 0.0) -> float:
        """Queue one device->host transfer; returns its completion time.
        ``ready`` is when the payload leaves the device (the device
        clock's completion) — the link cannot ship bytes it has not
        received."""
        with self._lock:
            dur = self.rtt + nbytes / self.bandwidth
            start = max(self.now, self.clock.read_busy_until, ready)
            self.clock.read_busy_until = start + dur
            self.read_time_total += dur
            return self.clock.read_busy_until

    def charge_write(self, nbytes: int, ready: float = 0.0) -> float:
        with self._lock:
            dur = self.rtt + nbytes / self.bandwidth
            start = max(self.now, self.clock.write_busy_until, ready)
            self.clock.write_busy_until = start + dur
            self.write_time_total += dur
            return self.clock.write_busy_until

    def read_completion(self) -> float:
        return self.clock.read_busy_until


class HostShard:
    """One host's slice of the distributed store: local devices behind a
    NIC link. ``link=None`` models a local shard (no network hop) — the
    single-shard store degenerates to the old one-host behavior."""

    def __init__(self, shard_id: int, devices: Sequence[Backend],
                 link: Optional[NICLink] = None):
        self.shard_id = int(shard_id)
        self.devices = list(devices)
        self.link = link

    def device_for(self, layer: int, chunk: int) -> Backend:
        return self.devices[(layer + chunk) % len(self.devices)]

    def read_async(self, dev: Backend, key: str)\
            -> Tuple["np.ndarray", float]:
        """Read ``key`` from ``dev`` through this shard's link: device
        service first, then the NIC transfer queued behind the link's
        earlier transfers."""
        data, dev_done = dev.read_async(key)
        if self.link is not None:
            return data, self.link.charge_read(data.nbytes, ready=dev_done)
        return data, dev_done

    def write_through(self, dev: Backend, key: str, data) -> float:
        done = dev.write(key, data)
        if self.link is not None:
            return self.link.charge_write(data.nbytes,
                                          ready=float(done or 0.0))
        return done

    def sync_clock(self, now: float) -> None:
        if self.link is not None:
            self.link.now = now
        for d in self.devices:
            if isinstance(d, SimulatedSSD):
                d.now = now

    def read_completion(self) -> float:
        done = self.link.read_completion() if self.link is not None else 0.0
        for d in self.devices:
            if isinstance(d, SimulatedSSD):
                done = max(done, d.read_completion())
        return done

    def read_service_total(self) -> float:
        """Accrued read service seconds on this shard (devices + link) —
        thread-confined to the shard's async worker, so per-task deltas
        are race-free without a global lock."""
        total = (self.link.read_time_total if self.link is not None
                 else 0.0)
        for d in self.devices:
            if isinstance(d, SimulatedSSD):
                total += d.read_time_total
        return total

    def n_timed(self) -> int:
        return sum(1 for d in self.devices if isinstance(d, SimulatedSSD))


@dataclasses.dataclass(frozen=True)
class ShardTopology:
    """Placement policy: which shard owns which (layer, chunk) — and,
    for the scheduler, which links a layer read touches. Pure math (no
    device handles), so planning code can price per-link contention
    without importing storage state."""

    n_shards: int
    placement: str = "layer"              # "layer" | "chunk"

    def __post_init__(self):
        if self.placement not in PLACEMENTS:
            raise ValueError(f"placement {self.placement!r} not in "
                             f"{PLACEMENTS}")

    def shard_for(self, layer: int, chunk: int) -> int:
        if self.n_shards <= 1:
            return 0
        if self.placement == "layer":
            return layer % self.n_shards
        return chunk % self.n_shards

    def links_for_layer(self, layer: int) -> Tuple[int, ...]:
        """Link ids a full layer read fans over."""
        if self.n_shards <= 1:
            return (0,)
        if self.placement == "layer":
            return (layer % self.n_shards,)
        return tuple(range(self.n_shards))

    def link_of_layer(self, layer: int) -> Optional[int]:
        """The single owning link of a layer, or None when the layer
        stripes several links (chunk placement) — per-link profiler
        samples and per-link replay apply only in the single-link case."""
        links = self.links_for_layer(layer)
        return links[0] if len(links) == 1 else None

    def to_json(self) -> dict:
        return {"n_shards": self.n_shards, "placement": self.placement}

    @classmethod
    def from_json(cls, data: dict) -> "ShardTopology":
        return cls(int(data.get("n_shards", 1)),
                   str(data.get("placement", "layer")))


def make_shards(n_shards: int, devices_per_shard: int, kind: str = "ssd",
                *, root: Optional[str] = None,
                nic_bw: float = NIC_BW, nic_rtt: float = NIC_RTT,
                budget_bytes: Optional[int] = None) -> List[HostShard]:
    """Build a homogeneous shard set. With ``n_shards == 1`` the shard
    still gets a NIC link (one host, one host link) so single- vs
    multi-shard comparisons vary only the shard count, not the model."""
    shards = []
    for s in range(n_shards):
        if kind == "dram":
            devs = [DRAMBackend() for _ in range(devices_per_shard)]
        elif kind == "ssd":
            devs = [SimulatedSSD() for _ in range(devices_per_shard)]
        elif kind == "file":
            assert root is not None
            devs = [FileBackend(os.path.join(root, f"shard{s}", f"dev{i}"))
                    for i in range(devices_per_shard)]
        else:
            raise ValueError(kind)
        link = (NICLink(nic_bw, nic_rtt, shard_id=s)
                if nic_bw is not None else None)
        shards.append(HostShard(s, devs, link))
    if budget_bytes is not None:
        # budget applies to the flattened hot tier (the chunk store
        # wraps all shard devices in one StorageArray)
        pass
    return shards


def flatten_shards(shards: Sequence[HostShard],
                   budget_bytes: Optional[int] = None) -> StorageArray:
    devs: List[Backend] = []
    for s in shards:
        devs.extend(s.devices)
    return StorageArray(devs, budget_bytes=budget_bytes)
