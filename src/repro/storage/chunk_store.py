"""Chunk-based storage manager (paper §4.2).

Layout problem: hidden states are *generated* layer-before-token (one layer
of the whole batch at a time, autoregressively growing in tokens) but
*restored* token-before-layer (all tokens of one layer as a batch). The
store therefore:

  * keys data by (session, stream, layer, chunk): a chunk holds
    ``chunk_tokens`` consecutive tokens of one layer — the restoration unit;
  * distributes the chunks of a layer **round-robin across devices** so a
    layer read aggregates the bandwidth of all devices (paper: multiple
    SSDs; here: backend array, possibly simulated);
  * never reserves a layer's worth of contiguous space (output length is
    unpredictable — chunks allocate incrementally, no internal
    fragmentation beyond the final partial chunk).

Chunk size is 128 tokens on TPU (MXU/lane alignment; the paper uses 64 on
GPU — see DESIGN.md §2). Partial chunks live in a staging dict until full
or flushed.

Streams: "h" (hidden states), "kv" (offloaded KV layers), "tok" (token
ids), "state" (SSM recurrent states). A JSON manifest per session makes the
store self-describing — the serving engine's crash-recovery path rebuilds
sessions from it.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import urllib.parse
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config.hardware import TPU_CHUNK_TOKENS
from repro.storage.aio import AsyncIOEngine, ReadTicket
from repro.storage.backend import Backend, SimulatedSSD, StorageArray
from repro.storage.shard import HostShard, ShardTopology, flatten_shards


def _enc(session: str) -> str:
    """Key-encode a session id: ids may contain '/' (e.g. tenant/user),
    which would collide with the key separator."""
    return urllib.parse.quote(session, safe="")


def _key(session: str, stream: str, layer: int, chunk: int) -> str:
    return f"{_enc(session)}/{stream}/L{layer}/C{chunk}"


def _meta_key(session: str) -> str:
    return f"{_enc(session)}/meta/L0/C0"


@dataclasses.dataclass
class AsyncRead:
    """A batched striped layer read + its virtual completion times.

    ``completion`` is the max over the per-device read clocks touched by
    this read (0.0 for backends without a timing model) — the moment the
    restoration executor may consume ``data``."""

    data: np.ndarray
    completion: float
    device_completions: List[float]


class LayerRead:
    """Handle for a submitted (possibly async) striped layer read.

    One ``ReadTicket`` per shard touched; ``wait()`` reassembles the
    chunks in token order and returns the same ``AsyncRead`` the inline
    path produces, so consumers are agnostic to sync vs async IO. The
    ``links`` attribute names the NIC links this read occupies — the
    executor reports them to the per-link contention pricer."""

    __slots__ = ("tickets", "_order", "_slice", "links", "layer")

    def __init__(self, tickets: List[ReadTicket],
                 order: List[Tuple[int, int]],
                 slice_: Tuple[int, int], links: Tuple[int, ...],
                 layer: int):
        self.tickets = tickets
        self._order = order              # chunk order -> (ticket, part) idx
        self._slice = slice_             # (offset, stop) into the concat
        self.links = links
        self.layer = layer

    def ready(self) -> bool:
        return all(t.ready() for t in self.tickets)

    @property
    def service(self) -> float:
        return sum(t.service for t in self.tickets)

    def wait(self, timeout: Optional[float] = None) -> AsyncRead:
        for t in self.tickets:
            t.wait(timeout)
        parts = [self.tickets[ti].parts[pi] for ti, pi in self._order]
        completions = [self.tickets[ti].completion for ti, _ in self._order]
        out = np.concatenate(parts, axis=0) if parts else \
            np.zeros((0,), np.float32)
        off, stop = self._slice
        return AsyncRead(out[off:stop], max(completions, default=0.0),
                         completions)


@dataclasses.dataclass
class _Partial:
    start_token: int
    rows: List[np.ndarray]

    @property
    def n(self) -> int:
        return sum(r.shape[0] for r in self.rows)


class ChunkStore:
    """Round-robin chunked store over a backend array.

    Optionally two-tiered: ``cold_devices`` is a second (cheaper, slower)
    array that cold sessions demote to wholesale
    (``demote_session_to_cold``); reads fall back hot -> cold per key, so
    a re-activated session may be tier-mixed (new chunks land hot while
    its history stays cold) without any promotion step. ``bytes_used``
    counts the HOT tier only — it is the budgeted quantity; the cold
    tier is accounted separately (``bytes_cold``)."""

    def __init__(self, devices: Optional[Sequence[Backend]] = None,
                 chunk_tokens: int = TPU_CHUNK_TOKENS,
                 cold_devices: Optional[Sequence[Backend]] = None,
                 *, shards: Optional[Sequence[HostShard]] = None,
                 placement: str = "layer",
                 budget_bytes: Optional[int] = None,
                 io_engine: Optional[AsyncIOEngine] = None):
        if shards is not None:
            # distributed store (DESIGN.md §15): each shard's devices sit
            # behind its NIC link; the flattened StorageArray keeps the
            # budget/pressure accounting identical to the one-host store
            self.shards: Optional[List[HostShard]] = list(shards)
            self.topology: Optional[ShardTopology] = ShardTopology(
                len(self.shards), placement)
            self.devices = flatten_shards(self.shards,
                                          budget_bytes=budget_bytes)
        else:
            assert devices is not None
            self.shards = None
            self.topology = None
            self.devices = (devices if isinstance(devices, StorageArray)
                            else list(devices))
        self.io_engine = io_engine
        self.cold = list(cold_devices) if cold_devices else None
        self.chunk_tokens = chunk_tokens
        self._partials: Dict[Tuple[str, str, int], _Partial] = {}
        # content-addressed sharing (DESIGN.md §12): a logical key may
        # alias a physical key owned by another session (fork / prefix
        # index). ``_refs`` counts holders of a physical key INCLUDING
        # its owner (absent entry == plain unshared key, refcount 1);
        # ``_orphans`` marks physical keys whose owning session no longer
        # holds them (owner dropped, or content shadowed out) — they are
        # excluded from per-session accounting, drops, and demotions, and
        # are physically deleted when their last alias/pin releases.
        self._alias: Dict[str, str] = {}
        self._refs: Dict[str, int] = {}
        self._orphans: set = set()
        self._pin_n = 0
        self._shadow_n = 0
        # RLock: the sharing bookkeeping runs inside append/flush, which
        # already hold the staging lock
        self._lock = threading.RLock()
        # device -> owning shard, for routing fallback-located chunks
        # through the correct NIC link
        self._dev_shard: Dict[int, HostShard] = {}
        if self.shards is not None:
            for s in self.shards:
                for d in s.devices:
                    self._dev_shard[id(d)] = s

    # ------------------------------------------------------------- placement
    def _shard_for(self, layer: int, chunk: int) -> Optional[HostShard]:
        if self.shards is None:
            return None
        return self.shards[self.topology.shard_for(layer, chunk)]

    def _device_for(self, layer: int, chunk: int) -> Backend:
        shard = self._shard_for(layer, chunk)
        if shard is not None:
            return shard.device_for(layer, chunk)
        return self.devices[(layer + chunk) % len(self.devices)]

    def _cold_for(self, layer: int, chunk: int) -> Backend:
        return self.cold[(layer + chunk) % len(self.cold)]

    def _backend_for(self, layer: int, chunk: int, key: str) -> Backend:
        """Device holding ``key``: hot placement first, cold fallback.
        In sharded mode, a key absent at its computed placement is
        searched across all shards — a store reopened with a different
        shard count (the owner map in the manifest records the writer's
        topology) still finds every chunk."""
        dev = self._device_for(layer, chunk)
        if not dev.contains(key):
            if self.shards is not None:
                for d in self.devices:
                    if d is not dev and d.contains(key):
                        return d
            if self.cold is not None:
                cold = self._cold_for(layer, chunk)
                if cold.contains(key):
                    return cold
        return dev

    def shard_topology(self) -> Optional[ShardTopology]:
        """Placement policy for planning code (None = one-host store)."""
        return self.topology

    def attach_io_engine(self, engine: Optional[AsyncIOEngine]) -> None:
        self.io_engine = engine

    def close(self) -> None:
        if self.io_engine is not None:
            self.io_engine.close()
            self.io_engine = None

    def _maybe_reclaim(self) -> None:
        """Budget check after a write burst (never under ``self._lock`` —
        pressure callbacks re-enter the store to demote/drop sessions)."""
        reclaim = getattr(self.devices, "maybe_reclaim", None)
        if reclaim is not None:
            reclaim()

    # ------------------------------------------------- shared-chunk plumbing
    @staticmethod
    def _coords(key: str) -> Tuple[int, int]:
        """(layer, chunk) parsed back out of a key (shadow suffixes on
        the chunk component are ignored — placement is by coordinates)."""
        parts = key.split("/")
        return int(parts[2][1:]), int(parts[3][1:].split("@")[0])

    def _resolve(self, key: str) -> str:
        """Physical key behind a logical key (identity when unshared)."""
        return self._alias.get(key, key)

    def _incref(self, phys: str) -> None:
        with self._lock:
            self._refs[phys] = self._refs.get(phys, 1) + 1

    def _release_phys(self, phys: str) -> None:
        """Drop one holder of a physical key; delete the bytes when the
        last holder releases (the deferred-eviction rule: a shared chunk
        outlives its owning session until the last referent lets go)."""
        with self._lock:
            r = self._refs.get(phys, 1) - 1
            if r <= 0:
                self._refs.pop(phys, None)
                self._orphans.discard(phys)
                for d in self._all_devices():
                    if d.contains(phys):
                        d.delete(phys)
                return
            if r == 1 and phys not in self._orphans:
                self._refs.pop(phys, None)     # back to plain owned
            else:
                self._refs[phys] = r

    def _prepare_write(self, session: str, stream: str, layer: int,
                       chunk: int) -> None:
        """Copy-on-write for the host tier: called before (over)writing a
        physical chunk/blob key. If the logical key aliases another
        session's data, the alias is dropped (the writer diverges onto
        its own bytes). If the key's current content is held by other
        sessions/pins, that content is shadowed out to a renamed physical
        key first, so the sharers keep reading the old bytes."""
        k = _key(session, stream, layer, chunk)
        with self._lock:
            phys = self._alias.pop(k, None)
            if phys is not None:
                self._release_phys(phys)
                return                          # k itself holds no bytes yet
            others = self._refs.get(k, 1) - (0 if k in self._orphans else 1)
            if others <= 0:
                return
            self._shadow_n += 1
            shadow = f"{k}@s{self._shadow_n}"
            dev = self._backend_for(layer, chunk, k)
            if dev.contains(k):
                dev.write(shadow, np.asarray(dev.peek(k)))
                dev.delete(k)
            for lk, pk in self._alias.items():
                if pk == k:
                    self._alias[lk] = shadow
            self._refs[shadow] = others
            self._refs.pop(k, None)
            self._orphans.discard(k)
            self._orphans.add(shadow)

    # ------------------------------------------------------------- sharing
    def pin_chunks(self, session: str, stream: str, layer: int,
                   chunks: Sequence[int]) -> List[str]:
        """Pin chunk content against deletion (prefix index): each pin id
        holds one reference to the chunk's current physical bytes, which
        therefore survive the owning session's eviction. Returns opaque
        pin ids for ``alias_chunk``/``unpin``."""
        ids = []
        with self._lock:
            for ci in chunks:
                phys = self._resolve(_key(session, stream, layer, int(ci)))
                self._pin_n += 1
                pid = f"__pin/{self._pin_n}"
                self._alias[pid] = phys
                self._incref(phys)
                ids.append(pid)
        return ids

    def chunk_rows(self, session: str, stream: str, layer: int,
                   chunk: int) -> int:
        """Rows (tokens) of a stored chunk, 0 when absent — the prefix
        index probes coverage with this before pinning (``pin_chunks``
        pins whatever key resolves; pinning a hole would hand out a pin
        id that aliases nothing)."""
        with self._lock:
            k = self._resolve(_key(session, stream, layer, int(chunk)))
            dev = self._backend_for(layer, int(chunk), k)
            return int(dev.nrows(k)) if dev.contains(k) else 0

    def unpin(self, pin_ids: Sequence[str]) -> None:
        with self._lock:
            for pid in pin_ids:
                phys = self._alias.pop(pid, None)
                if phys is not None:
                    self._release_phys(phys)

    def alias_chunk(self, session: str, stream: str, layer: int,
                    chunk: int, ref_key: str) -> None:
        """Map ``session``'s (stream, layer, chunk) onto existing bytes
        (``ref_key``: an ordinary key or a pin id). The new session reads
        the shared bytes; its first write to the chunk diverges onto its
        own copy (``_prepare_write``)."""
        logical = _key(session, stream, layer, chunk)
        with self._lock:
            phys = self._resolve(ref_key)
            old = self._alias.pop(logical, None)
            if old is not None:
                self._release_phys(old)
            self._alias[logical] = phys
            self._incref(phys)

    def share_session(self, src: str, dst: str, *, copy: bool = False)\
            -> int:
        """Alias every stored chunk/blob of ``src`` into ``dst`` (fork).
        ``copy=True`` materializes real copies instead (sharing-off
        reference behavior — byte-identical semantics, no dedup).
        Returns the number of keys shared/copied."""
        self.flush(src)
        prefix = _enc(src) + "/"
        dstp = _enc(dst) + "/"
        with self._lock:
            seen = set()
            for d in self._all_devices():
                for k in d.keys():
                    if (k.startswith(prefix) and "/meta/" not in k
                            and k not in self._orphans):
                        seen.add(k)
            seen.update(lk for lk in self._alias
                        if lk.startswith(prefix))
            for k in sorted(seen):
                newk = dstp + k[len(prefix):]
                layer, chunk = self._coords(k)
                phys = self._resolve(k)
                if copy:
                    dev = self._backend_for(layer, chunk, phys)
                    self._device_for(layer, chunk).write(
                        newk, np.asarray(dev.peek(phys)))
                else:
                    self._alias[newk] = phys
                    self._incref(phys)
        self._maybe_reclaim()
        return len(seen)

    @property
    def dedup_bytes(self) -> int:
        """Bytes that sharing avoided storing twice: one count of the
        physical bytes per session-visible alias (pins excluded — they
        keep data alive but do not stand for a second copy)."""
        saved = 0
        with self._lock:
            entries = [(lk, pk) for lk, pk in self._alias.items()
                       if not lk.startswith("__pin/")]
        for lk, pk in entries:
            layer, chunk = self._coords(pk)
            dev = self._backend_for(layer, chunk, pk)
            if dev.contains(pk):
                saved += dev.nbytes(pk)
        return saved

    # ----------------------------------------------------------------- write
    def append_tokens(self, session: str, stream: str, layer: int,
                      start_token: int, data: np.ndarray) -> None:
        """Append ``data`` (n_tokens, width) for one layer starting at
        ``start_token``; fills chunks and flushes the complete ones."""
        C = self.chunk_tokens
        with self._lock:
            key = (session, stream, layer)
            part = self._partials.get(key)
            if part is None:
                part = _Partial(start_token - start_token % C, [])
                pad = start_token - part.start_token
                if pad:
                    # resuming mid-chunk (multi-round session): recover the
                    # previously-flushed partial chunk as the prefix —
                    # through the alias map, so a forked/prefix-matched
                    # session seeds its divergent chunk from shared bytes
                    ci = part.start_token // C
                    kstr = self._resolve(_key(session, stream, layer, ci))
                    dev = self._backend_for(layer, ci, kstr)
                    if dev.contains(kstr):
                        prev = np.asarray(dev.read(kstr))[:pad]
                    else:
                        prev = np.zeros((0,) + data.shape[1:], data.dtype)
                    if prev.shape[0] < pad:
                        prev = np.concatenate(
                            [prev, np.zeros((pad - prev.shape[0],)
                                            + data.shape[1:], data.dtype)])
                    part.rows.append(prev)
                self._partials[key] = part
            part.rows.append(np.asarray(data))
            while part.n >= C:
                block = np.concatenate(part.rows, axis=0)
                chunk_idx = part.start_token // C
                self._prepare_write(session, stream, layer, chunk_idx)
                self._device_for(layer, chunk_idx).write(
                    _key(session, stream, layer, chunk_idx), block[:C])
                part.start_token += C
                part.rows = [block[C:]] if block.shape[0] > C else []

    def flush(self, session: str) -> None:
        """Persist all partial chunks of a session (padded to chunk size is
        NOT needed — partial chunks are stored at their true length)."""
        with self._lock:
            for (s, stream, layer), part in list(self._partials.items()):
                if s != session or part.n == 0:
                    continue
                block = np.concatenate(part.rows, axis=0)
                chunk_idx = part.start_token // self.chunk_tokens
                self._prepare_write(s, stream, layer, chunk_idx)
                self._device_for(layer, chunk_idx).write(
                    _key(session, stream, layer, chunk_idx), block)
                del self._partials[(s, stream, layer)]
        self._maybe_reclaim()

    def put_blob(self, session: str, stream: str, layer: int,
                 data: np.ndarray) -> None:
        """Whole-object write (SSM states, token ids)."""
        self._prepare_write(session, stream, layer, 0)
        self._device_for(layer, 0).write(_key(session, stream, layer, 0),
                                         np.asarray(data))
        self._maybe_reclaim()

    def get_blob(self, session: str, stream: str, layer: int) -> np.ndarray:
        key = self._resolve(_key(session, stream, layer, 0))
        return self._backend_for(layer, 0, key).read(key)

    def has_blob(self, session: str, stream: str, layer: int) -> bool:
        key = self._resolve(_key(session, stream, layer, 0))
        return self._backend_for(layer, 0, key).contains(key)

    # ------------------------------------------------------------------ read
    def read_layer(self, session: str, stream: str, layer: int,
                   n_tokens: int, start_token: int = 0) -> np.ndarray:
        """Restoration read: all chunks of one layer, token order.

        With SimulatedSSD devices the per-device clocks advance in parallel
        (round-robin striping aggregates bandwidth); completion time is
        queried via ``read_completion``."""
        return self.read_layer_async(session, stream, layer, n_tokens,
                                     start_token=start_token).data

    def read_layer_async(self, session: str, stream: str, layer: int,
                         n_tokens: int, start_token: int = 0) -> AsyncRead:
        """Batched striped read of one layer with completion times.

        Issues every chunk read up front (each device queues its own IOs
        on its clock) and returns the assembled array plus the per-device
        virtual completion times — the executor overlaps compute with the
        stripe instead of re-simulating the IO separately.

        ``start_token`` is the restore-skip entry point: only the chunks
        covering tokens [start_token, n_tokens) are read (and charged on
        the device clocks); the returned data starts at ``start_token``.

        In sharded mode every chunk read additionally occupies its
        shard's NIC link: ``done`` becomes the link completion, so the
        virtual timeline prices the network hop, and chunks on distinct
        shards overlap on distinct links."""
        C = self.chunk_tokens
        first = start_token // C
        n_chunks = (n_tokens + C - 1) // C
        parts = []
        completions = []
        for ci in range(first, n_chunks):
            key = self._resolve(_key(session, stream, layer, ci))
            data, done, _ = self._read_chunk_async(layer, ci, key)
            parts.append(data)
            completions.append(done)
        out = np.concatenate(parts, axis=0) if parts else \
            np.zeros((0,), np.float32)
        off = start_token - first * C
        return AsyncRead(out[off:n_tokens - first * C],
                         max(completions, default=0.0), completions)

    def _read_chunk_async(self, layer: int, chunk: int, key: str)\
            -> Tuple[np.ndarray, float, Optional[HostShard]]:
        """One chunk read routed through the owning shard's link (when
        sharded and hot); returns (data, virtual completion, shard)."""
        dev = self._backend_for(layer, chunk, key)
        shard = self._dev_shard.get(id(dev))
        if shard is not None and shard.link is not None:
            data, done = shard.read_async(dev, key)
            return data, done, shard
        data, done = dev.read_async(key)
        return data, done, shard

    # ------------------------------------------------------- async submission
    def _shard_groups(self, session: str, stream: str, layer: int,
                      n_tokens: int, start_token: int):
        """Chunk reads of one layer grouped by owning shard, in chunk
        order: {shard_key: [(chunk_pos, dev, shard, key), ...]}."""
        C = self.chunk_tokens
        first = start_token // C
        n_chunks = (n_tokens + C - 1) // C
        groups: Dict[int, List] = {}
        pos = 0
        for ci in range(first, n_chunks):
            key = self._resolve(_key(session, stream, layer, ci))
            dev = self._backend_for(layer, ci, key)
            shard = self._dev_shard.get(id(dev))
            sid = shard.shard_id if shard is not None else 0
            groups.setdefault(sid, []).append((pos, dev, shard, key))
            pos += 1
        off = start_token - first * C
        return groups, (off, n_tokens - first * C)

    def submit_layer_read(self, session: str, stream: str, layer: int,
                          n_tokens: int, start_token: int = 0) -> LayerRead:
        """Submit a striped layer read: one ticket per shard on the async
        IO engine (reads overlap the caller for real), or — with no
        engine attached — already-completed tickets from inline reads, so
        consumers never branch on the IO mode."""
        groups, slice_ = self._shard_groups(session, stream, layer,
                                            n_tokens, start_token)
        tickets: List[ReadTicket] = []
        order: List[Optional[Tuple[int, int]]] = [None] * sum(
            len(g) for g in groups.values())
        links = []
        for sid in sorted(groups):
            entries = groups[sid]
            keys = [e[3] for e in entries]
            if entries and entries[0][2] is not None \
                    and entries[0][2].link is not None:
                links.append(sid)
            ti = len(tickets)
            for pi, (pos, _, _, _) in enumerate(entries):
                order[pos] = (ti, pi)
            if self.io_engine is not None:
                shard0 = entries[0][2]
                service_fn = (shard0.read_service_total
                              if shard0 is not None else None)
                reads = []
                for _, dev, shard, key in entries:
                    if shard is not None and shard.link is not None:
                        reads.append((
                            lambda s=shard, d=dev, k=key: s.read_async(d, k),
                            service_fn))
                    else:
                        reads.append((
                            lambda d=dev, k=key: d.read_async(k),
                            service_fn))
                tickets.append(self.io_engine.submit(sid, keys, reads))
            else:
                parts, completion, service = [], 0.0, 0.0
                for _, dev, shard, key in entries:
                    if shard is not None and shard.link is not None:
                        before = shard.read_service_total()
                        data, done = shard.read_async(dev, key)
                        service += shard.read_service_total() - before
                    else:
                        data, done = dev.read_async(key)
                    parts.append(data)
                    completion = max(completion, done)
                tickets.append(ReadTicket.completed(
                    keys, parts, completion, sid, service))
        return LayerRead(tickets, order, slice_, tuple(links), layer)

    def submit_blob_read(self, session: str, stream: str,
                         layer: int) -> ReadTicket:
        """Async whole-object read (encoder blobs, SSM states)."""
        key = self._resolve(_key(session, stream, layer, 0))
        dev = self._backend_for(layer, 0, key)
        shard = self._dev_shard.get(id(dev))
        sid = shard.shard_id if shard is not None else 0
        if self.io_engine is not None:
            if shard is not None and shard.link is not None:
                read = (lambda: shard.read_async(dev, key),
                        shard.read_service_total)
            else:
                read = (lambda: dev.read_async(key), None)
            return self.io_engine.submit(sid, [key], [read])
        if shard is not None and shard.link is not None:
            data, done = shard.read_async(dev, key)
        else:
            data, done = dev.read_async(key)
        return ReadTicket.completed([key], [data], done, sid)

    def layer_available(self, session: str, stream: str, layer: int,
                        n_tokens: int = 1) -> bool:
        """True when the chunks covering tokens [0, n_tokens) exist.

        Checking chunk 0 alone is wrong for multi-chunk layers: a crash
        mid-save leaves a prefix of chunks, and the restore path must not
        claim the full range is readable."""
        C = self.chunk_tokens
        n_chunks = max((n_tokens + C - 1) // C, 1)
        with self._lock:
            part = self._partials.get((session, stream, layer))
            part_start = part.start_token if part is not None else None
            part_end = (part.start_token + part.n
                        if part is not None else None)
        for ci in range(n_chunks):
            lo = ci * C
            hi = min(n_tokens, lo + C)
            kstr = self._resolve(_key(session, stream, layer, ci))
            dev = self._backend_for(layer, ci, kstr)
            # the stream's final chunk is stored at its true (short)
            # length — existence alone does not cover the range
            if dev.contains(kstr) and lo + dev.nrows(kstr) >= hi:
                continue
            # staged (unflushed) rows are chunk-aligned and include any
            # recovered flushed prefix, so they cover [part_start, part_end)
            if (part_start is not None and part_start <= lo
                    and part_end >= hi):
                continue
            return False
        return True

    # ------------------------------------------------------------- manifest
    def put_manifest(self, session: str, manifest: dict) -> None:
        if self.topology is not None:
            # owner map: the topology the session's chunks were placed
            # under — a store reopened with a different shard count uses
            # it to locate stripes (and a remote restore to target hosts)
            manifest = dict(manifest)
            manifest["shards"] = self.topology.to_json()
        raw = np.frombuffer(json.dumps(manifest).encode(), dtype=np.uint8)
        self.devices[0].write(_meta_key(session), raw.copy())
        if self.cold is not None:
            # hot copy is now authoritative — a stale cold copy from an
            # earlier tier demotion must not shadow future drops/reads
            self.cold[0].delete(_meta_key(session))
        self._maybe_reclaim()

    def get_manifest(self, session: str) -> Optional[dict]:
        key = _meta_key(session)
        dev = self._backend_for(0, 0, key)
        if not dev.contains(key):
            return None
        # metadata path: admission/eviction policies poll manifests every
        # step — must not charge the simulated-device read clock
        raw = dev.peek(key)
        return json.loads(raw.tobytes().decode())

    def _all_devices(self) -> List[Backend]:
        return list(self.devices) + (self.cold or [])

    def sessions(self) -> List[str]:
        out = set()
        for d in self._all_devices():
            for k in d.keys():
                if "/meta/" in k:
                    out.add(urllib.parse.unquote(k.split("/")[0]))
        return sorted(out)

    # -------------------------------------------------------------- eviction
    def _drop_key(self, d: Backend, k: str) -> int:
        """Owner-side delete of one device key; returns bytes physically
        freed. Shared keys are NOT deleted — the owner's hold is dropped
        and the bytes become an orphan kept alive by the remaining
        aliases/pins (deferred eviction)."""
        with self._lock:
            if k in self._orphans:
                return 0                      # not this session's bytes
            if self._refs.get(k, 1) > 1:
                self._refs[k] -= 1
                self._orphans.add(k)
                return 0
            self._refs.pop(k, None)
            freed = d.nbytes(k)
            d.delete(k)
            return freed

    def _drop_aliases(self, prefix: str) -> None:
        with self._lock:
            for lk in [lk for lk in self._alias if lk.startswith(prefix)]:
                self._release_phys(self._alias.pop(lk))

    def drop_session(self, session: str) -> None:
        with self._lock:
            for key in list(self._partials):
                if key[0] == session:
                    del self._partials[key]
        prefix = _enc(session) + "/"
        for d in self._all_devices():
            for k in d.keys():
                if k.startswith(prefix):
                    self._drop_key(d, k)
        self._drop_aliases(prefix)

    def drop_stream(self, session: str, stream: str) -> int:
        """Delete every chunk of one (session, stream); returns bytes
        freed (shared chunks drop the owner's hold without freeing —
        their bytes free when the last referent releases). Used by the
        capacity ladder to degrade a session to a cheaper representation
        (e.g. drop 'h' after re-encoding)."""
        with self._lock:
            for key in list(self._partials):
                if key[0] == session and key[1] == stream:
                    del self._partials[key]
        prefix = f"{_enc(session)}/{stream}/"
        freed = 0
        for d in self._all_devices():
            for k in d.keys():
                if k.startswith(prefix):
                    freed += self._drop_key(d, k)
        self._drop_aliases(prefix)
        return freed

    # ------------------------------------------------------ tier demotion
    def demote_session_to_cold(self, session: str) -> int:
        """Move every stored key of a session from the hot tier to the
        cold tier (DRAM -> SSD for idle sessions). Returns bytes moved
        (0 when there is no cold tier or nothing hot remains). Reads fall
        back to the cold tier per key, so demotion is transparent to
        restoration; new appends for a re-activated session land hot."""
        if self.cold is None:
            return 0
        self.flush(session)
        prefix = _enc(session) + "/"
        moved = 0
        for d in self.devices:
            for k in d.keys():
                if not k.startswith(prefix):
                    continue
                # demotion of a shared chunk is deferred until its last
                # referent releases it: a sibling session may be resident
                # and restoring from these bytes right now
                if k in self._orphans or self._refs.get(k, 1) > 1:
                    continue
                layer, chunk = self._coords(k)
                data = d.peek(k)
                self._cold_for(layer, chunk).write(k, np.asarray(data))
                moved += data.nbytes
                d.delete(k)
        return moved

    def stream_in_cold(self, session: str, stream: str) -> bool:
        """True when any chunk of (session, stream) lives in the cold
        tier — the capacity ladder uses this to re-encode a stream back
        into the tier it came from (a cold-demoted session's int8
        re-encode must not re-enter the budgeted hot tier)."""
        if self.cold is None:
            return False
        prefix = f"{_enc(session)}/{stream}/"
        return any(k.startswith(prefix) for d in self.cold for k in d.keys())

    def demote_stream_to_cold(self, session: str, stream: str) -> int:
        """Move one (session, stream)'s chunks hot -> cold; returns bytes
        moved. Stream-scoped sibling of ``demote_session_to_cold``."""
        if self.cold is None:
            return 0
        self.flush(session)
        prefix = f"{_enc(session)}/{stream}/"
        moved = 0
        for d in self.devices:
            for k in d.keys():
                if not k.startswith(prefix):
                    continue
                if k in self._orphans or self._refs.get(k, 1) > 1:
                    continue                   # deferred: shared bytes
                layer, chunk = self._coords(k)
                data = d.peek(k)
                self._cold_for(layer, chunk).write(k, np.asarray(data))
                moved += data.nbytes
                d.delete(k)
        return moved

    # -------------------------------------------------------------- accounting
    @property
    def bytes_used(self) -> int:
        """Hot-tier footprint — the budgeted quantity."""
        return sum(d.bytes_used for d in self.devices)

    @property
    def bytes_cold(self) -> int:
        return sum(d.bytes_used for d in self.cold) if self.cold else 0

    def bytes_for(self, session: str, stream: Optional[str] = None,
                  include_cold: bool = True) -> int:
        """Per-session (optionally per-stream) stored bytes, both tiers
        by default. Computed by key scan — always consistent with the
        devices, including after a FileBackend reopen.

        Dedup-aware: shared bytes are counted once, toward the session
        that OWNS the physical key. Aliased streams (a fork reading a
        sibling's chunks) and orphans (bytes whose owner dropped but that
        pins/aliases keep alive) cost the session nothing — the capacity
        manager therefore never evicts a session to reclaim bytes it is
        not actually paying for."""
        prefix = _enc(session) + "/" + (f"{stream}/" if stream else "")
        devices = self._all_devices() if include_cold else list(self.devices)
        return sum(d.nbytes(k) for d in devices
                   for k in d.keys()
                   if k.startswith(prefix) and k not in self._orphans)

    def sync_clocks(self, now: float) -> None:
        for d in self.devices:
            if isinstance(d, SimulatedSSD):
                d.now = now
        if self.shards is not None:
            for s in self.shards:
                s.sync_clock(now)

    def read_completion(self) -> float:
        done = 0.0
        for d in self.devices:
            if isinstance(d, SimulatedSSD):
                done = max(done, d.read_completion())
        if self.shards is not None:
            for s in self.shards:
                done = max(done, s.read_completion())
        return done

    def n_timed_devices(self) -> int:
        """Devices with a read-service clock (SimulatedSSD), hot + cold —
        0 means reads carry no timing (plain DRAM) and the restoration
        profiler has no IO signal to fold."""
        return sum(1 for d in self._all_devices()
                   if isinstance(d, SimulatedSSD))

    def read_service_total(self) -> float:
        """Accumulated per-device read service seconds across all timed
        devices. The restoration profiler snapshots this around each IO
        task: the delta, divided by the device count (stripes are served
        in parallel), is the task's observed IO-stream seconds — queueing
        behind other sessions' reads is excluded, so the sample is the
        contention-free service time the cost model's 1-stream rate
        predicts."""
        return sum(d.read_time_total for d in self._all_devices()
                   if isinstance(d, SimulatedSSD))
