"""Chunk-based storage manager (paper §4.2).

Layout problem: hidden states are *generated* layer-before-token (one layer
of the whole batch at a time, autoregressively growing in tokens) but
*restored* token-before-layer (all tokens of one layer as a batch). The
store therefore:

  * keys data by (session, stream, layer, chunk): a chunk holds
    ``chunk_tokens`` consecutive tokens of one layer — the restoration unit;
  * distributes the chunks of a layer **round-robin across devices** so a
    layer read aggregates the bandwidth of all devices (paper: multiple
    SSDs; here: backend array, possibly simulated);
  * never reserves a layer's worth of contiguous space (output length is
    unpredictable — chunks allocate incrementally, no internal
    fragmentation beyond the final partial chunk).

Chunk size is 128 tokens on TPU (MXU/lane alignment; the paper uses 64 on
GPU — see DESIGN.md §2). Partial chunks live in a staging dict until full
or flushed.

Streams: "h" (hidden states), "kv" (offloaded KV layers), "tok" (token
ids), "state" (SSM recurrent states). A JSON manifest per session makes the
store self-describing — the serving engine's crash-recovery path rebuilds
sessions from it.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import urllib.parse
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

from repro.config.hardware import TPU_CHUNK_TOKENS
from repro.storage.backend import Backend, SimulatedSSD


def _enc(session: str) -> str:
    """Key-encode a session id: ids may contain '/' (e.g. tenant/user),
    which would collide with the key separator."""
    return urllib.parse.quote(session, safe="")


def _key(session: str, stream: str, layer: int, chunk: int) -> str:
    return f"{_enc(session)}/{stream}/L{layer}/C{chunk}"


def _meta_key(session: str) -> str:
    return f"{_enc(session)}/meta/L0/C0"


@dataclasses.dataclass
class AsyncRead:
    """A batched striped layer read + its virtual completion times.

    ``completion`` is the max over the per-device read clocks touched by
    this read (0.0 for backends without a timing model) — the moment the
    restoration executor may consume ``data``."""

    data: np.ndarray
    completion: float
    device_completions: List[float]


@dataclasses.dataclass
class _Partial:
    start_token: int
    rows: List[np.ndarray]

    @property
    def n(self) -> int:
        return sum(r.shape[0] for r in self.rows)


class ChunkStore:
    """Round-robin chunked store over a backend array."""

    def __init__(self, devices: Sequence[Backend],
                 chunk_tokens: int = TPU_CHUNK_TOKENS):
        self.devices = list(devices)
        self.chunk_tokens = chunk_tokens
        self._partials: Dict[Tuple[str, str, int], _Partial] = {}
        self._lock = threading.Lock()

    # ------------------------------------------------------------- placement
    def _device_for(self, layer: int, chunk: int) -> Backend:
        return self.devices[(layer + chunk) % len(self.devices)]

    # ----------------------------------------------------------------- write
    def append_tokens(self, session: str, stream: str, layer: int,
                      start_token: int, data: np.ndarray) -> None:
        """Append ``data`` (n_tokens, width) for one layer starting at
        ``start_token``; fills chunks and flushes the complete ones."""
        C = self.chunk_tokens
        with self._lock:
            key = (session, stream, layer)
            part = self._partials.get(key)
            if part is None:
                part = _Partial(start_token - start_token % C, [])
                pad = start_token - part.start_token
                if pad:
                    # resuming mid-chunk (multi-round session): recover the
                    # previously-flushed partial chunk as the prefix
                    ci = part.start_token // C
                    dev = self._device_for(layer, ci)
                    kstr = _key(session, stream, layer, ci)
                    if dev.contains(kstr):
                        prev = np.asarray(dev.read(kstr))[:pad]
                    else:
                        prev = np.zeros((0,) + data.shape[1:], data.dtype)
                    if prev.shape[0] < pad:
                        prev = np.concatenate(
                            [prev, np.zeros((pad - prev.shape[0],)
                                            + data.shape[1:], data.dtype)])
                    part.rows.append(prev)
                self._partials[key] = part
            part.rows.append(np.asarray(data))
            while part.n >= C:
                block = np.concatenate(part.rows, axis=0)
                chunk_idx = part.start_token // C
                self._device_for(layer, chunk_idx).write(
                    _key(session, stream, layer, chunk_idx), block[:C])
                part.start_token += C
                part.rows = [block[C:]] if block.shape[0] > C else []

    def flush(self, session: str) -> None:
        """Persist all partial chunks of a session (padded to chunk size is
        NOT needed — partial chunks are stored at their true length)."""
        with self._lock:
            for (s, stream, layer), part in list(self._partials.items()):
                if s != session or part.n == 0:
                    continue
                block = np.concatenate(part.rows, axis=0)
                chunk_idx = part.start_token // self.chunk_tokens
                self._device_for(layer, chunk_idx).write(
                    _key(session, stream, layer, chunk_idx), block)
                del self._partials[(s, stream, layer)]

    def put_blob(self, session: str, stream: str, layer: int,
                 data: np.ndarray) -> None:
        """Whole-object write (SSM states, token ids)."""
        self._device_for(layer, 0).write(_key(session, stream, layer, 0),
                                         np.asarray(data))

    def get_blob(self, session: str, stream: str, layer: int) -> np.ndarray:
        return self._device_for(layer, 0).read(_key(session, stream, layer, 0))

    # ------------------------------------------------------------------ read
    def read_layer(self, session: str, stream: str, layer: int,
                   n_tokens: int) -> np.ndarray:
        """Restoration read: all chunks of one layer, token order.

        With SimulatedSSD devices the per-device clocks advance in parallel
        (round-robin striping aggregates bandwidth); completion time is
        queried via ``read_completion``."""
        return self.read_layer_async(session, stream, layer, n_tokens).data

    def read_layer_async(self, session: str, stream: str, layer: int,
                         n_tokens: int) -> AsyncRead:
        """Batched striped read of one layer with completion times.

        Issues every chunk read up front (each device queues its own IOs
        on its clock) and returns the assembled array plus the per-device
        virtual completion times — the executor overlaps compute with the
        stripe instead of re-simulating the IO separately."""
        C = self.chunk_tokens
        n_chunks = (n_tokens + C - 1) // C
        parts = []
        completions = []
        for ci in range(n_chunks):
            data, done = self._device_for(layer, ci).read_async(
                _key(session, stream, layer, ci))
            parts.append(data)
            completions.append(done)
        out = np.concatenate(parts, axis=0)
        return AsyncRead(out[:n_tokens], max(completions, default=0.0),
                         completions)

    def layer_available(self, session: str, stream: str, layer: int,
                        n_tokens: int = 1) -> bool:
        """True when the chunks covering tokens [0, n_tokens) exist.

        Checking chunk 0 alone is wrong for multi-chunk layers: a crash
        mid-save leaves a prefix of chunks, and the restore path must not
        claim the full range is readable."""
        C = self.chunk_tokens
        n_chunks = max((n_tokens + C - 1) // C, 1)
        with self._lock:
            part = self._partials.get((session, stream, layer))
            part_start = part.start_token if part is not None else None
            part_end = (part.start_token + part.n
                        if part is not None else None)
        for ci in range(n_chunks):
            lo = ci * C
            hi = min(n_tokens, lo + C)
            dev = self._device_for(layer, ci)
            kstr = _key(session, stream, layer, ci)
            # the stream's final chunk is stored at its true (short)
            # length — existence alone does not cover the range
            if dev.contains(kstr) and lo + dev.nrows(kstr) >= hi:
                continue
            # staged (unflushed) rows are chunk-aligned and include any
            # recovered flushed prefix, so they cover [part_start, part_end)
            if (part_start is not None and part_start <= lo
                    and part_end >= hi):
                continue
            return False
        return True

    # ------------------------------------------------------------- manifest
    def put_manifest(self, session: str, manifest: dict) -> None:
        raw = np.frombuffer(json.dumps(manifest).encode(), dtype=np.uint8)
        self.devices[0].write(_meta_key(session), raw.copy())

    def get_manifest(self, session: str) -> Optional[dict]:
        if not self.devices[0].contains(_meta_key(session)):
            return None
        raw = self.devices[0].read(_meta_key(session))
        return json.loads(raw.tobytes().decode())

    def sessions(self) -> List[str]:
        out = set()
        for d in self.devices:
            for k in d.keys():
                if "/meta/" in k:
                    out.add(urllib.parse.unquote(k.split("/")[0]))
        return sorted(out)

    # -------------------------------------------------------------- eviction
    def drop_session(self, session: str) -> None:
        with self._lock:
            for key in list(self._partials):
                if key[0] == session:
                    del self._partials[key]
        prefix = _enc(session) + "/"
        for d in self.devices:
            for k in d.keys():
                if k.startswith(prefix):
                    d.delete(k)

    # -------------------------------------------------------------- accounting
    @property
    def bytes_used(self) -> int:
        return sum(d.bytes_used for d in self.devices)

    def sync_clocks(self, now: float) -> None:
        for d in self.devices:
            if isinstance(d, SimulatedSSD):
                d.now = now

    def read_completion(self) -> float:
        done = 0.0
        for d in self.devices:
            if isinstance(d, SimulatedSSD):
                done = max(done, d.read_completion())
        return done
