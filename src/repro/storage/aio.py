"""Async IO engine: per-shard submission queues with bounded depth.

Before this module, every storage read in the restoration executor ran
synchronously in the engine thread — ``FileBackend.read`` blocked on
``np.load`` inline, so "IO overlaps compute" was true only of the
virtual timeline, never of wall clock. The engine here makes the
overlap real:

  * one submission queue + one worker thread **per shard** — reads for
    different shards proceed in parallel (the whole point of striping),
    while reads within a shard stay serial (one NIC, one queue — which
    also thread-confines that shard's virtual clocks to its worker);
  * bounded in-flight depth via ``Queue(maxsize=depth)`` — ``submit``
    backpressures instead of queueing unbounded staging memory;
  * staging buffers: a ``ReadTicket`` owns the parts read so far; the
    consumer calls ``wait()`` (or polls ``ready()``) and takes the
    assembled payload exactly once.

The executor turns each ``io_h``/``io_kv``/``io_enc`` leg into a
submit/complete pair: submit on task dispatch, complete (wait) at the
first consumer — the projection for hidden stripes, the sink write for
KV, the cross-projection for encoder blobs. See DESIGN.md §15.
"""
from __future__ import annotations

import queue
import threading
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple


class ReadTicket:
    """Handle for one submitted read: staging buffer + completion event.

    ``parts`` fills in submission order (one entry per key) inside the
    shard worker; ``wait()`` blocks until the last part lands. The
    virtual-clock completion (max over parts) and accrued service
    seconds ride along so the executor can keep feeding the profiler
    and the measured timeline from async reads."""

    __slots__ = ("keys", "parts", "completion", "service", "shard_id",
                 "_event", "error")

    def __init__(self, keys: Sequence[str], shard_id: int):
        self.keys = list(keys)
        self.parts: List[Any] = []
        self.completion = 0.0            # virtual-clock finish (max of parts)
        self.service = 0.0               # accrued service seconds (profiler)
        self.shard_id = shard_id
        self._event = threading.Event()
        self.error: Optional[BaseException] = None

    def ready(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> List[Any]:
        if not self._event.wait(timeout):
            raise TimeoutError(f"read of {self.keys} did not complete")
        if self.error is not None:
            raise self.error
        return self.parts

    @classmethod
    def completed(cls, keys: Sequence[str], parts: Sequence[Any],
                  completion: float, shard_id: int = 0,
                  service: float = 0.0) -> "ReadTicket":
        """An already-finished ticket — the sync-fallback path (no IO
        engine attached) returns these so consumers never branch."""
        t = cls(keys, shard_id)
        t.parts = list(parts)
        t.completion = completion
        t.service = service
        t._event.set()
        return t


class _Submission:
    __slots__ = ("reads", "ticket")

    def __init__(self, reads, ticket):
        # reads: list of (callable () -> (data, vclock_done), service_fn)
        self.reads = reads
        self.ticket = ticket


class AsyncIOEngine:
    """Per-shard submission-queue thread pool with bounded depth.

    ``submit(shard_id, reads)`` enqueues one ticket whose reads all
    target that shard; the shard's worker drains its queue serially.
    ``depth`` bounds in-flight tickets per shard — a full queue blocks
    the submitter (the executor's dispatch), which is the staging-memory
    backpressure. Workers are daemon threads; ``close()`` drains and
    joins them."""

    def __init__(self, n_shards: int, depth: int = 4):
        self.n_shards = int(n_shards)
        self.depth = int(depth)
        self._queues: List["queue.Queue[Optional[_Submission]]"] = [
            queue.Queue(maxsize=self.depth) for _ in range(self.n_shards)]
        self._workers: List[threading.Thread] = []
        self._closed = False
        self.submitted = 0
        self.completed = 0
        self._stats_lock = threading.Lock()
        for s in range(self.n_shards):
            w = threading.Thread(target=self._worker, args=(s,),
                                 name=f"aio-shard{s}", daemon=True)
            w.start()
            self._workers.append(w)

    def _worker(self, shard_id: int) -> None:
        q = self._queues[shard_id]
        while True:
            sub = q.get()
            if sub is None:
                q.task_done()
                return
            ticket = sub.ticket
            try:
                for fn, service_fn in sub.reads:
                    before = service_fn() if service_fn else 0.0
                    data, done = fn()
                    after = service_fn() if service_fn else 0.0
                    ticket.parts.append(data)
                    ticket.completion = max(ticket.completion, done)
                    ticket.service += max(0.0, after - before)
            except BaseException as e:        # surface to the waiter
                ticket.error = e
            finally:
                ticket._event.set()
                with self._stats_lock:
                    self.completed += 1
                q.task_done()

    def submit(self, shard_id: int, keys: Sequence[str],
               reads: Sequence[Tuple[Callable[[], Tuple[Any, float]],
                                     Optional[Callable[[], float]]]]
               ) -> ReadTicket:
        """Enqueue the reads (all on ``shard_id``) behind one ticket.
        Blocks when the shard already has ``depth`` tickets in flight."""
        if self._closed:
            raise RuntimeError("AsyncIOEngine is closed")
        ticket = ReadTicket(keys, shard_id % self.n_shards)
        self._queues[ticket.shard_id].put(_Submission(list(reads), ticket))
        with self._stats_lock:
            self.submitted += 1
        return ticket

    def drain(self) -> None:
        for q in self._queues:
            q.join()

    def close(self) -> None:
        if self._closed:
            return
        self._closed = True
        for q in self._queues:
            q.put(None)
        for w in self._workers:
            w.join(timeout=5.0)

    def stats(self) -> Dict[str, int]:
        with self._stats_lock:
            return {"submitted": self.submitted, "completed": self.completed,
                    "n_shards": self.n_shards, "depth": self.depth}
